"""Chakra-style execution traces (paper §2.1, §4.3).

A kernel-granularity workload representation: per-rank DAGs of compute and
communication kernels with dependencies (MLCommons Chakra ET, ref [43]).
ASTRA-sim 3.0's end-to-end flow parses these and *decomposes* each kernel
into the common fine-grained representation, so compute and communication
kernels contend for the same CUs with no artificial one-kernel-at-a-time
restriction (paper §4.3).

Traces are a first-class workload: hand one to
``repro.core.backends.simulate(trace, infra, fidelity=...)`` and it runs at
any fidelity tier.  The fine tier uses :class:`TraceExecutor` below — each
rank's kernel dispatched onto the detailed Cluster when *that rank's*
dependencies are met, so launch skew and stragglers propagate through the
semaphores exactly as on real hardware.  The dependency bookkeeping itself
lives in the tier-agnostic
:class:`~repro.core.backends.workload.DagScheduler`, shared with the
coarse/analytic trace executors.

``ExecutionTrace.to_json`` / ``from_json`` round-trip the structure
(runtime timestamps stripped), so external Chakra-style JSON traces can be
imported, validated, and fed straight to ``simulate``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

if TYPE_CHECKING:                                       # no runtime cycle
    from ..serve.metrics import LatencyStats

from .backends.base import SimResult
from .backends.workload import DagScheduler
from .cluster import Cluster
from .collectives import ALGORITHMS
from .mscclpp import Program, lower_program
from .operations import ReduceOp
from .workload import Kernel, Workgroup

#: per-node runtime state, never serialized
_RUNTIME_FIELDS = ("start_ns", "end_ns")

#: optional fields elided from JSON at their defaults, so dumps of traces
#: that don't use them stay byte-identical to the pre-serving format
_DEFAULT_ELIDED = {"start_after_ns": 0.0, "req_done": [],
                   "src_rank": -1, "dst_rank": -1}


@dataclass
class ETNode:
    """One node of a per-rank execution trace."""
    nid: int
    rank: int
    name: str
    kind: str                       # "comp" | "coll"
    deps: List[int] = field(default_factory=list)
    # comp attributes
    flops: float = 0.0
    bytes_moved: float = 0.0
    # coll attributes
    coll_id: int = -1               # groups the per-rank halves of a collective
    coll_kind: str = ""             # all_reduce | all_gather | ... | p2p
    coll_bytes: int = 0             # per-rank payload
    algorithm: str = "ring"
    # p2p endpoints (coll_kind == "p2p"): every other rank is a bystander
    src_rank: int = -1
    dst_rank: int = -1
    # serving attributes: earliest release time (request arrival — the node
    # is held even when its deps resolve sooner) and the ids of requests
    # whose completion this node marks (request -> node tagging for
    # per-request latency extraction)
    start_after_ns: float = 0.0
    req_done: List[int] = field(default_factory=list)
    # runtime
    start_ns: float = -1.0
    end_ns: float = -1.0


@dataclass
class ExecutionTrace:
    num_ranks: int
    nodes: List[ETNode] = field(default_factory=list)
    _next: int = 0

    def comp(self, rank: int, name: str, flops: float, bytes_moved: float = 0,
             deps: Optional[List[ETNode]] = None,
             start_after_ns: float = 0.0) -> ETNode:
        n = ETNode(self._next, rank, name, "comp",
                   deps=[d.nid for d in deps or []], flops=flops,
                   bytes_moved=bytes_moved, start_after_ns=start_after_ns)
        self._next += 1
        self.nodes.append(n)
        return n

    def coll(self, coll_id: int, kind: str, per_rank_bytes: int,
             algorithm: str = "ring",
             deps_by_rank: Optional[Dict[int, List[ETNode]]] = None,
             name: str = "", start_after_ns: float = 0.0) -> List[ETNode]:
        """Add the per-rank halves of one collective."""
        out = []
        for r in range(self.num_ranks):
            deps = [d.nid for d in (deps_by_rank or {}).get(r, [])]
            n = ETNode(self._next, r, name or f"{kind}#{coll_id}", "coll",
                       deps=deps, coll_id=coll_id, coll_kind=kind,
                       coll_bytes=per_rank_bytes, algorithm=algorithm,
                       start_after_ns=start_after_ns)
            self._next += 1
            self.nodes.append(n)
            out.append(n)
        return out

    def p2p(self, coll_id: int, size_bytes: int, src: int, dst: int,
            deps_by_rank: Optional[Dict[int, List[ETNode]]] = None,
            name: str = "", start_after_ns: float = 0.0) -> List[ETNode]:
        """Add the two halves of a point-to-point transfer (KV-cache
        handoff): ``src`` streams ``size_bytes`` to ``dst``; every other
        rank is a pure bystander with no ops.  Returns ``[src_half,
        dst_half]``."""
        out = []
        for r in (src, dst):
            deps = [d.nid for d in (deps_by_rank or {}).get(r, [])]
            n = ETNode(self._next, r, name or f"p2p#{coll_id}", "coll",
                       deps=deps, coll_id=coll_id, coll_kind="p2p",
                       coll_bytes=size_bytes, algorithm="direct",
                       src_rank=src, dst_rank=dst,
                       start_after_ns=start_after_ns)
            self._next += 1
            self.nodes.append(n)
            out.append(n)
        return out

    # ------------------------------------------------------------- JSON I/O
    def _node_structs(self) -> List[dict]:
        """Semantic per-node dicts: runtime timestamps stripped, default
        optional fields elided (shared by :meth:`to_json` and
        :meth:`content_hash`, so a dump and its re-import hash equal)."""
        return [{k: v for k, v in n.__dict__.items()
                 if k not in _RUNTIME_FIELDS
                 and not (k in _DEFAULT_ELIDED and v == _DEFAULT_ELIDED[k])}
                for n in self.nodes]

    def to_json(self) -> str:
        """Serialize the trace *structure*: runtime start/end timestamps are
        stripped, so a dump taken after a run round-trips to a clean trace."""
        return json.dumps({"num_ranks": self.num_ranks,
                           "nodes": self._node_structs()}, indent=1)

    def content_hash(self) -> str:
        """Canonical sha256 over the trace's semantic content — the sweep
        cache's workload key.  Runtime fields (``start_ns``/``end_ns``)
        are excluded, so a trace hashes identically before and after a
        run; ``from_json(to_json(t))`` hashes equal to ``t``."""
        from .canonical import content_hash
        return content_hash({"kind": "ExecutionTrace",
                             "num_ranks": self.num_ranks,
                             "nodes": self._node_structs()})

    @staticmethod
    def from_json(text: str) -> "ExecutionTrace":
        """Parse, validate, and import a Chakra-style JSON trace.

        Accepts the :meth:`to_json` format (``{"num_ranks": N, "nodes":
        [...]}``) and, for older dumps, a bare node list (``num_ranks``
        then inferred from the highest rank).  Unknown node keys, bad
        kinds, malformed collectives and dangling dependencies all raise
        ``ValueError`` with the offending node named; stray runtime fields
        (``start_ns``/``end_ns``) in old dumps are ignored.
        """
        d = json.loads(text)
        if isinstance(d, list):                      # legacy bare node list
            raw_nodes, num_ranks = d, None
        elif isinstance(d, dict):
            raw_nodes = d.get("nodes")
            num_ranks = d.get("num_ranks")
            if not isinstance(raw_nodes, list):
                raise ValueError("trace JSON must carry a 'nodes' list")
        else:
            raise ValueError(f"trace JSON must be an object or list, "
                             f"got {type(d).__name__}")
        known = {f for f in ETNode.__dataclass_fields__}
        nodes: List[ETNode] = []
        for i, nd in enumerate(raw_nodes):
            if not isinstance(nd, dict):
                raise ValueError(f"node #{i}: expected an object")
            unknown = set(nd) - known
            if unknown:
                raise ValueError(f"node #{i}: unknown field(s) "
                                 f"{sorted(unknown)}; valid: {sorted(known)}")
            for req in ("nid", "rank", "kind"):
                if req not in nd:
                    raise ValueError(f"node #{i}: missing required "
                                     f"field {req!r}")
            clean = {k: v for k, v in nd.items() if k not in _RUNTIME_FIELDS}
            clean.setdefault("name", f"{clean['kind']}#{clean['nid']}")
            nodes.append(ETNode(**clean))
        if num_ranks is None:
            num_ranks = max((n.rank for n in nodes), default=-1) + 1
        et = ExecutionTrace(num_ranks=num_ranks, nodes=nodes,
                            _next=max((n.nid for n in nodes), default=-1) + 1)
        et.validate()
        return et

    def reset_runtime(self) -> None:
        """Clear per-node runtime timestamps (before a fresh run)."""
        for n in self.nodes:
            n.start_ns = -1.0
            n.end_ns = -1.0

    def validate(self) -> None:
        if self.num_ranks < 1:
            raise ValueError(f"trace needs num_ranks >= 1, "
                             f"got {self.num_ranks}")
        ids = {n.nid for n in self.nodes}
        if len(ids) != len(self.nodes):
            raise ValueError("duplicate node ids in trace")
        colls: Dict[int, Dict[int, ETNode]] = {}
        for n in self.nodes:
            if n.kind not in ("comp", "coll"):
                raise ValueError(f"node {n.nid}: bad kind {n.kind!r}")
            if not (0 <= n.rank < self.num_ranks):
                raise ValueError(f"node {n.nid}: rank {n.rank} outside "
                                 f"0..{self.num_ranks - 1}")
            if n.start_after_ns < 0:
                raise ValueError(f"node {n.nid}: negative start_after_ns "
                                 f"{n.start_after_ns}")
            if n.kind == "coll":
                if n.coll_id < 0 or not n.coll_kind:
                    raise ValueError(f"node {n.nid}: collective node needs "
                                     f"coll_id >= 0 and a coll_kind")
                if (n.coll_kind, n.algorithm) not in ALGORITHMS:
                    raise ValueError(
                        f"node {n.nid}: no algorithm "
                        f"{(n.coll_kind, n.algorithm)!r}; known: "
                        f"{sorted(ALGORITHMS)}")
                if n.coll_kind == "p2p":
                    for role, r in (("src", n.src_rank), ("dst", n.dst_rank)):
                        if not (0 <= r < self.num_ranks):
                            raise ValueError(
                                f"node {n.nid}: p2p {role}_rank {r} outside "
                                f"0..{self.num_ranks - 1}")
                    if n.src_rank == n.dst_rank:
                        raise ValueError(f"node {n.nid}: p2p src_rank == "
                                         f"dst_rank ({n.src_rank})")
                    if n.rank not in (n.src_rank, n.dst_rank):
                        raise ValueError(
                            f"node {n.nid}: p2p half on rank {n.rank} but "
                            f"the transfer is {n.src_rank} -> {n.dst_rank}")
                group = colls.setdefault(n.coll_id, {})
                prev = group.get(n.rank)
                if prev is not None:
                    raise ValueError(
                        f"node {n.nid}: rank {n.rank} appears twice in "
                        f"collective {n.coll_id} (node {prev.nid}) — each "
                        f"collective instance needs a fresh coll_id")
                group[n.rank] = n
            for d in n.deps:
                if d not in ids:
                    raise ValueError(f"node {n.nid}: missing dep {d}")
        # each collective is lowered once, from any member: the group must
        # cover every participating rank exactly once and agree on its
        # parameters, or the executors would deadlock (missing rank) or
        # silently diverge.  Full collectives span every rank; p2p spans
        # exactly its {src, dst} pair.
        for cid, group in colls.items():
            any_node = next(iter(group.values()))
            if any_node.coll_kind == "p2p":
                want = {any_node.src_rank, any_node.dst_rank}
            else:
                want = set(range(self.num_ranks))
            if set(group) != want:
                missing = sorted(want - set(group))
                extra = sorted(set(group) - want)
                raise ValueError(
                    f"collective {cid}: "
                    + (f"missing rank halves for ranks {missing}"
                       if missing else f"stray rank halves on ranks {extra}"))
            sig = {(n.coll_kind, n.coll_bytes, n.algorithm,
                    n.src_rank, n.dst_rank) for n in group.values()}
            if len(sig) != 1:
                raise ValueError(f"collective {cid}: inconsistent "
                                 f"kind/bytes/algorithm across ranks: "
                                 f"{sorted(sig)}")
        self._check_acyclic()

    def _check_acyclic(self) -> None:
        """Reject dependency cycles: the DagScheduler would otherwise run
        zero nodes and report the whole trace as incomplete, with no hint
        of which deps are circular.  Iterative tricolor DFS."""
        by_id = {n.nid: n for n in self.nodes}
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {nid: WHITE for nid in by_id}
        for root in by_id:
            if color[root] != WHITE:
                continue
            color[root] = GRAY
            stack = [(root, iter(by_id[root].deps))]
            path = [root]
            while stack:
                nid, it = stack[-1]
                for d in it:
                    if color[d] == GRAY:
                        cyc = path[path.index(d):] + [d]
                        raise ValueError(
                            "dependency cycle: "
                            + " -> ".join(str(x) for x in cyc))
                    if color[d] == WHITE:
                        color[d] = GRAY
                        stack.append((d, iter(by_id[d].deps)))
                        path.append(d)
                        break
                else:
                    color[nid] = BLACK
                    stack.pop()
                    path.pop()


@dataclass
class TraceResult(SimResult):
    """Result of an ExecutionTrace run (any fidelity tier).

    Shares :class:`~repro.core.backends.base.SimResult` with
    ``CollectiveResult`` so sweep scripts handle programs and traces
    uniformly; adds the per-node interval map.

    ``latency`` is populated by serving runs
    (:meth:`repro.serve.ServingScenario.simulate`): per-request tail
    latency statistics (p50/p95/p99/p999, mean, max, goodput) extracted
    from ``node_times`` via the trace's request tags.  Plain trace runs
    leave it ``None``.
    """
    node_times: Dict[int, Tuple[float, float]] = field(default_factory=dict)
    latency: Optional["LatencyStats"] = None

    @property
    def per_rank_end_ns(self) -> List[float]:
        """Back-compat alias of ``per_rank_done_ns``."""
        return self.per_rank_done_ns


def collective_program(node: ETNode, num_ranks: int, workgroups: int,
                       protocol: str = "put") -> Program:
    """Generate the MSCCL++ program for one trace collective node."""
    if node.coll_kind == "p2p":
        from .collectives import p2p_transfer
        return p2p_transfer(num_ranks, node.coll_bytes, workgroups,
                            protocol=protocol, src=node.src_rank,
                            dst=node.dst_rank)
    gen = ALGORITHMS[(node.coll_kind, node.algorithm)]
    try:
        return gen(num_ranks, node.coll_bytes, workgroups, protocol=protocol)
    except TypeError:
        return gen(num_ranks, node.coll_bytes, workgroups)


class TraceExecutor:
    """Dispatch an ExecutionTrace onto the fine-grained Cluster."""

    def __init__(self, trace: ExecutionTrace, cluster: Cluster,
                 comp_workgroups: int = 8, coll_workgroups: int = 4,
                 flops_per_cu_cycle: float = 2048.0,
                 protocol: str = "put"):
        self.trace = trace
        self.dag = DagScheduler(trace)         # validates the trace
        self.cluster = cluster
        self.comp_wgs = comp_workgroups
        self.coll_wgs = coll_workgroups
        self.flops_per_cu_cycle = flops_per_cu_cycle
        self.protocol = protocol
        # cache one lowered program per coll_id; kernels dispatched per rank
        self._coll_kernels: Dict[int, Dict[int, Kernel]] = {}

    # ---------------------------------------------------------------- running
    def run(self, until_ns: float = 1e12) -> TraceResult:
        for n in self.dag.roots():
            self._launch(n)
        self.cluster.run(until_ns)
        return self.dag.result(self.cluster.engine, "fine")

    def _launch(self, node: ETNode) -> None:
        # arrival release: hold the node past its resolved deps until
        # start_after_ns (request arrival jitter), then dispatch for real
        eng = self.cluster.engine
        release_ps = int(round(node.start_after_ns * 1000.0))
        if release_ps > eng.now_ps:
            eng.schedule_abs_ps(release_ps, self._dispatch, node)
            return
        self._dispatch(node)

    def _dispatch(self, node: ETNode) -> None:
        node.start_ns = self.cluster.engine.now
        if node.kind == "comp":
            kernel = self._comp_kernel(node)
        else:
            kernel = self._coll_kernel(node)
        kernel.on_done = lambda k, t, nid=node.nid: self._complete(nid, t)
        self.cluster.dispatch(kernel)

    def _comp_kernel(self, node: ETNode) -> Kernel:
        cfg = self.cluster.gpu_config
        ncu = min(self.comp_wgs, cfg.num_cus)
        # roofline-style kernel time: max of compute and memory terms,
        # expressed as CU-occupancy cycles split over the workgroups
        flop_cycles = node.flops / (ncu * self.flops_per_cu_cycle)
        mem_ns = node.bytes_moved / (
            self.cluster.noc.mem_GBps_per_channel * self.cluster.noc.mem_channels)
        cycles = max(flop_cycles, mem_ns / cfg.cycle_ns, 1.0)
        wgs = [Workgroup([ReduceOp(cycles=int(cycles), tag=node.name)],
                         num_wavefronts=1) for _ in range(ncu)]
        return Kernel(wgs, name=node.name, gpu=node.rank)

    def _coll_kernel(self, node: ETNode) -> Kernel:
        if node.coll_id not in self._coll_kernels:
            prog = collective_program(node, self.trace.num_ranks,
                                      self.coll_wgs, self.protocol)
            # namespace semaphores per collective instance: monotonic
            # counters must not collide across collectives on one cluster
            kernels = lower_program(prog, sem_base=node.coll_id * 100_000)
            self._coll_kernels[node.coll_id] = {k.gpu: k for k in kernels}
        return self._coll_kernels[node.coll_id][node.rank]

    def _complete(self, nid: int, t: float) -> None:
        for node in self.dag.complete(nid, t):
            self._launch(node)
