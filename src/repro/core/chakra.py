"""Chakra-style execution traces (paper §2.1, §4.3).

A kernel-granularity workload representation: per-rank DAGs of compute and
communication kernels with dependencies (MLCommons Chakra ET, ref [43]).
ASTRA-sim 3.0's end-to-end flow parses these and *decomposes* each kernel
into the common fine-grained representation, so compute and communication
kernels contend for the same CUs with no artificial one-kernel-at-a-time
restriction (paper §4.3).

The executor below implements that flow on the detailed Cluster.  Collective
nodes sharing a ``coll_id`` across ranks are lowered from one MSCCL++
program; each rank's kernel is dispatched when *that rank's* dependencies
are met, so launch skew and stragglers propagate through the semaphores
exactly as on real hardware.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .cluster import Cluster
from .collectives import ALGORITHMS
from .mscclpp import Program, lower_program
from .operations import ReduceOp
from .workload import Kernel, Workgroup


@dataclass
class ETNode:
    """One node of a per-rank execution trace."""
    nid: int
    rank: int
    name: str
    kind: str                       # "comp" | "coll"
    deps: List[int] = field(default_factory=list)
    # comp attributes
    flops: float = 0.0
    bytes_moved: float = 0.0
    # coll attributes
    coll_id: int = -1               # groups the per-rank halves of a collective
    coll_kind: str = ""             # all_reduce | all_gather | ...
    coll_bytes: int = 0             # per-rank payload
    algorithm: str = "ring"
    # runtime
    start_ns: float = -1.0
    end_ns: float = -1.0


@dataclass
class ExecutionTrace:
    num_ranks: int
    nodes: List[ETNode] = field(default_factory=list)
    _next: int = 0

    def comp(self, rank: int, name: str, flops: float, bytes_moved: float = 0,
             deps: Optional[List[ETNode]] = None) -> ETNode:
        n = ETNode(self._next, rank, name, "comp",
                   deps=[d.nid for d in deps or []], flops=flops,
                   bytes_moved=bytes_moved)
        self._next += 1
        self.nodes.append(n)
        return n

    def coll(self, coll_id: int, kind: str, per_rank_bytes: int,
             algorithm: str = "ring",
             deps_by_rank: Optional[Dict[int, List[ETNode]]] = None,
             name: str = "") -> List[ETNode]:
        """Add the per-rank halves of one collective."""
        out = []
        for r in range(self.num_ranks):
            deps = [d.nid for d in (deps_by_rank or {}).get(r, [])]
            n = ETNode(self._next, r, name or f"{kind}#{coll_id}", "coll",
                       deps=deps, coll_id=coll_id, coll_kind=kind,
                       coll_bytes=per_rank_bytes, algorithm=algorithm)
            self._next += 1
            self.nodes.append(n)
            out.append(n)
        return out

    def to_json(self) -> str:
        return json.dumps([n.__dict__ for n in self.nodes], indent=1)

    def validate(self) -> None:
        ids = {n.nid for n in self.nodes}
        for n in self.nodes:
            for d in n.deps:
                if d not in ids:
                    raise ValueError(f"node {n.nid}: missing dep {d}")


@dataclass
class TraceResult:
    time_ns: float
    events: int
    node_times: Dict[int, Tuple[float, float]]
    per_rank_end_ns: List[float]


class TraceExecutor:
    """Dispatch an ExecutionTrace onto the fine-grained Cluster."""

    def __init__(self, trace: ExecutionTrace, cluster: Cluster,
                 comp_workgroups: int = 8, coll_workgroups: int = 4,
                 flops_per_cu_cycle: float = 2048.0,
                 protocol: str = "put"):
        trace.validate()
        self.trace = trace
        self.cluster = cluster
        self.comp_wgs = comp_workgroups
        self.coll_wgs = coll_workgroups
        self.flops_per_cu_cycle = flops_per_cu_cycle
        self.protocol = protocol
        self.by_id = {n.nid: n for n in trace.nodes}
        self.pending_deps = {n.nid: len(n.deps) for n in trace.nodes}
        self.dependents: Dict[int, List[int]] = {}
        for n in trace.nodes:
            for d in n.deps:
                self.dependents.setdefault(d, []).append(n.nid)
        self.unfinished = len(trace.nodes)
        # cache one lowered program per coll_id; kernels dispatched per rank
        self._coll_kernels: Dict[int, Dict[int, Kernel]] = {}

    # ---------------------------------------------------------------- running
    def run(self, until_ns: float = 1e12) -> TraceResult:
        for n in self.trace.nodes:
            if self.pending_deps[n.nid] == 0:
                self._launch(n)
        self.cluster.run(until_ns)
        if self.unfinished:
            left = [n.nid for n in self.trace.nodes if n.end_ns < 0]
            raise RuntimeError(f"trace incomplete, nodes left: {left[:10]}")
        per_rank = [0.0] * self.trace.num_ranks
        for n in self.trace.nodes:
            per_rank[n.rank] = max(per_rank[n.rank], n.end_ns)
        return TraceResult(
            time_ns=max(per_rank), events=self.cluster.engine.events_processed,
            node_times={n.nid: (n.start_ns, n.end_ns)
                        for n in self.trace.nodes},
            per_rank_end_ns=per_rank)

    def _launch(self, node: ETNode) -> None:
        node.start_ns = self.cluster.engine.now
        if node.kind == "comp":
            kernel = self._comp_kernel(node)
        else:
            kernel = self._coll_kernel(node)
        kernel.on_done = lambda k, t, nid=node.nid: self._complete(nid, t)
        self.cluster.dispatch(kernel)

    def _comp_kernel(self, node: ETNode) -> Kernel:
        cfg = self.cluster.gpu_config
        ncu = min(self.comp_wgs, cfg.num_cus)
        # roofline-style kernel time: max of compute and memory terms,
        # expressed as CU-occupancy cycles split over the workgroups
        flop_cycles = node.flops / (ncu * self.flops_per_cu_cycle)
        mem_ns = node.bytes_moved / (
            self.cluster.noc.mem_GBps_per_channel * self.cluster.noc.mem_channels)
        cycles = max(flop_cycles, mem_ns / cfg.cycle_ns, 1.0)
        wgs = [Workgroup([ReduceOp(cycles=int(cycles), tag=node.name)],
                         num_wavefronts=1) for _ in range(ncu)]
        return Kernel(wgs, name=node.name, gpu=node.rank)

    def _coll_kernel(self, node: ETNode) -> Kernel:
        if node.coll_id not in self._coll_kernels:
            gen = ALGORITHMS[(node.coll_kind, node.algorithm)]
            try:
                prog = gen(self.trace.num_ranks, node.coll_bytes,
                           self.coll_wgs, protocol=self.protocol)
            except TypeError:
                prog = gen(self.trace.num_ranks, node.coll_bytes,
                           self.coll_wgs)
            # namespace semaphores per collective instance: monotonic
            # counters must not collide across collectives on one cluster
            kernels = lower_program(prog, sem_base=node.coll_id * 100_000)
            self._coll_kernels[node.coll_id] = {k.gpu: k for k in kernels}
        return self._coll_kernels[node.coll_id][node.rank]

    def _complete(self, nid: int, t: float) -> None:
        node = self.by_id[nid]
        node.end_ns = t
        self.unfinished -= 1
        for dep_id in self.dependents.get(nid, []):
            self.pending_deps[dep_id] -= 1
            if self.pending_deps[dep_id] == 0:
                self._launch(self.by_id[dep_id])
