"""Pallas TPU kernel for the RG-LRU gated linear recurrence (Griffin).

    h_t = a_t * h_{t-1} + b_t        (elementwise in the feature dim)

Feature dim tiled over a parallel grid axis (lane-aligned blocks of 128);
time tiled over a sequential grid axis with the running h carried in VMEM
scratch; within a time block a ``fori_loop`` steps the recurrence (the op
is bandwidth-bound, so the VPU loop is fine — the win is keeping h
resident in VMEM instead of round-tripping HBM each step).

Layout: a, b: (B, T, R) -> h: (B, T, R).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(a_ref, b_ref, h0_ref, y_ref, h_ref, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)     # (block_t, block_r)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):                      # h: (1, block_r)
        at = jax.lax.dynamic_slice_in_dim(a, t, 1, axis=0)
        bt = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)
        h = at * h + bt
        y_ref[0, pl.ds(t, 1), :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h


def rg_lru_scan(a, b, h0, *, block_t: int = 128, block_r: int = 512,
                interpret: bool = False):
    """a, b: (B, T, R); h0: (B, R) -> h: (B, T, R) (all steps' states)."""
    B, T, R = a.shape
    block_t = min(block_t, T)
    block_r = min(block_r, R)
    assert T % block_t == 0 and R % block_r == 0, (T, R, block_t, block_r)
    grid = (B, R // block_r, T // block_t)
    spec = pl.BlockSpec((1, block_t, block_r),
                        lambda bb, ri, ti: (bb, ti, ri))
    h0_spec = pl.BlockSpec((1, block_r), lambda bb, ri, ti: (bb, ri))
    scratch = [_VMEM((1, block_r), jnp.float32)] if _VMEM is not None else []
    params = {}
    if pltpu is not None and not interpret:
        try:
            params["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            pass
    kern = functools.partial(_kernel, block_t=block_t)
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[spec, spec, h0_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, R), a.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(a, b, h0)
