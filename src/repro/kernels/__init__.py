from . import ops, ref
from .flash_attention import flash_attention
from .rg_lru import rg_lru_scan
from .rwkv6_wkv import wkv6

__all__ = ["ops", "ref", "flash_attention", "wkv6", "rg_lru_scan"]
