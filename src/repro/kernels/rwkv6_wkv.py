"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked parallel form).

Per (batch, head) the recurrence over T steps with state S in R^{NxN}:
    y_t = (S_{t-1} + (u * k_t) v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
is evaluated chunk by chunk (grid innermost dim sequential, state carried
in VMEM scratch).  Within a chunk all decay factors appear as
exp(c_i - c_j) with i >= j <= 0 — numerically safe (DESIGN.md §7).

Layout: r, k, v, logw: (B, H, T, N); u: (H, N); y: (B, H, T, N).
Chunk length C is the sublane-friendly 32; N = head dim (64 for rwkv6-7b).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_ref, *,
            chunk: int, n: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)          # log decay, < 0
    u = u_ref[0].astype(jnp.float32)             # (1, N) -> broadcast
    S = state_ref[...]                           # (N, N)

    c = jnp.cumsum(w, axis=0)                    # inclusive
    c_prev = c - w                               # exclusive
    c_end = c[-1:]                               # (1, N)

    # intra-chunk scores[t,s] = sum_n r[t,n] k[s,n] exp(c_prev[t]-c[s]) s<t
    expo = c_prev[:, None, :] - c[None, :, :]    # (C, C, N)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    expo = jnp.where(mask[:, :, None], expo, -jnp.inf)
    scores = jnp.einsum("tn,sn,tsn->ts", r, k, jnp.exp(expo),
                        preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus diagonal term: (r . (u*k)) v
    y += jnp.sum(r * u * k, axis=-1, keepdims=True) * v
    # carried state contribution
    y += jax.lax.dot_general(r * jnp.exp(c_prev), S,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update
    khat = k * jnp.exp(c_end - c)                # (C, N)
    state_ref[...] = S * jnp.exp(c_end[0])[:, None] + jax.lax.dot_general(
        khat, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def wkv6(r, k, v, logw, u, *, chunk: int = 32, interpret: bool = False):
    """r,k,v,logw: (B, H, T, N); u: (H, N) -> y: (B, H, T, N)."""
    B, H, T, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    grid = (B, H, nc)
    spec = pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0))
    u_spec = pl.BlockSpec((1, N), lambda b, h, c: (h, 0))
    scratch = [_VMEM((N, N), jnp.float32)] if _VMEM is not None else []
    params = {}
    if pltpu is not None and not interpret:
        try:
            params["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            pass
    kern = functools.partial(_kernel, chunk=chunk, n=N)
    return pl.pallas_call(
        kern, grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, N), r.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(r, k, v, logw, u)
