"""Pure-jnp oracles for every Pallas kernel.

The oracles are deliberately the SIMPLEST possible formulations (direct
masked softmax; step-by-step recurrences via lax.scan) — independent of the
blockwise/chunked math used by both the kernels and the model code, so a
bug in the clever form cannot hide in the reference.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, kv_len=None):
    """q: (B, H, T, D); k, v: (B, Kh, S, D) -> (B, H, T, D)."""
    B, H, T, D = q.shape
    Kh, S = k.shape[1], k.shape[2]
    G = H // Kh
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    tpos = jnp.arange(T)[:, None]
    spos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= spos <= tpos
    if window:
        mask &= spos > tpos - window
    if kv_len is not None:
        mask &= spos < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Sequential RWKV-6 recurrence.  r,k,v,logw: (B,H,T,N); u: (H,N)."""
    B, H, T, N = r.shape

    def step(S, xs):
        rt, kt, vt, wt = xs          # (B, H, N)
        kv = jnp.einsum("bhn,bhz->bhnz", kt, vt)
        y = jnp.einsum("bhn,bhnz->bhz", rt, S + u[None, :, :, None] * kv)
        S = S * jnp.exp(wt)[..., None] + kv
        return S, y

    xs = tuple(a.transpose(2, 0, 1, 3).astype(jnp.float32)
               for a in (r, k, v, logw))
    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype)     # (B, H, T, N)


def rg_lru_ref(a, b, h0):
    """Sequential h_t = a_t h_{t-1} + b_t.  a, b: (B,T,R); h0: (B,R)."""
    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    xs = (a.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32))
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return hs.transpose(1, 0, 2).astype(a.dtype)
