"""jit-ready wrappers dispatching Pallas kernels vs jnp references.

On TPU the Pallas kernels run natively; on CPU the pure-jnp reference path
is used (or the kernels in interpret mode when ``force="interpret"`` —
that's how the test suite validates kernel bodies without hardware).
"""

from __future__ import annotations

from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention as _flash
from .rg_lru import rg_lru_scan as _rg_lru
from .rwkv6_wkv import wkv6 as _wkv6


def _use_pallas(force: Optional[str]) -> Optional[bool]:
    if force == "pallas":
        return True
    if force == "interpret":
        return None          # pallas with interpret=True
    if force == "ref":
        return False
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=0, kv_len=None,
              block_q=128, block_k=128, force: Optional[str] = None):
    """Model-layout wrapper: q (B,T,H,D), kv (B,S,Kh,D) -> (B,T,H,D)."""
    mode = _use_pallas(force)
    if mode is False:
        return ref.attention_ref(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 causal=causal, window=window,
                                 kv_len=kv_len).transpose(0, 2, 1, 3)
    out = _flash(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                 v.transpose(0, 2, 1, 3), causal=causal, window=window,
                 kv_len=kv_len, block_q=block_q, block_k=block_k,
                 interpret=(mode is None))
    return out.transpose(0, 2, 1, 3)


def wkv6(r, k, v, logw, u, *, chunk=32, force: Optional[str] = None):
    """(B,H,T,N) in/out."""
    mode = _use_pallas(force)
    if mode is False:
        return ref.wkv6_ref(r, k, v, logw, u)
    return _wkv6(r, k, v, logw, u, chunk=chunk, interpret=(mode is None))


def rg_lru_scan(a, b, h0, *, block_t=128, block_r=512,
                force: Optional[str] = None):
    """(B,T,R) in/out."""
    mode = _use_pallas(force)
    if mode is False:
        return ref.rg_lru_ref(a, b, h0)
    return _rg_lru(a, b, h0, block_t=block_t, block_r=block_r,
                   interpret=(mode is None))
