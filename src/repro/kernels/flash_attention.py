"""Pallas TPU flash attention (causal / windowed, GQA).

Grid: (B, H, q_blocks, kv_blocks) — kv innermost, sequential ("arbitrary"),
carrying the online-softmax state (m, l, acc) in VMEM scratch.  Q/K/V are
tiled into (block_q x head_dim) / (block_k x head_dim) VMEM blocks; the
MXU sees (block_q x head_dim) @ (head_dim x block_k) and
(block_q x block_k) @ (block_k x head_dim) matmuls, with block sizes
multiples of the 128-lane tile.  GQA is expressed in the K/V index_map
(kv head = h // group), so K/V are never repeated in HBM.

Layout contract (ops.py transposes from the model's (B, T, H, D)):
  q: (B, H, T, D);  k, v: (B, Kh, S, D);  out: (B, H, T, D).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU backend params are importable on CPU for interpret mode
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, nk: int, causal: bool,
            window: int, scale: float, kv_len: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    run = True
    if causal:
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run if isinstance(run, bool) else run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        if kv_len is not None:
            mask &= k_pos < kv_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    kv_len: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jnp.ndarray:
    """q: (B, H, T, D); k, v: (B, Kh, S, D) -> (B, H, T, D)."""
    B, H, T, D = q.shape
    Kh, S = k.shape[1], k.shape[2]
    G = H // Kh
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    nq, nk = T // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    grid = (B, H, nq, nk)
    q_spec = pl.BlockSpec((1, 1, block_q, D),
                          lambda b, h, qi, ki: (b, h, qi, 0))
    k_spec = pl.BlockSpec((1, 1, block_k, D),
                          lambda b, h, qi, ki: (b, h // G, ki, 0))
    v_spec = pl.BlockSpec((1, 1, block_k, D),
                          lambda b, h, qi, ki: (b, h // G, ki, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, D),
                          lambda b, h, qi, ki: (b, h, qi, 0))
    scratch = [
        _VMEM((block_q, 1), jnp.float32),
        _VMEM((block_q, 1), jnp.float32),
        _VMEM((block_q, D), jnp.float32),
    ] if _VMEM is not None else []

    kern = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                             nk=nk, causal=causal, window=window,
                             scale=scale, kv_len=kv_len)
    params = {}
    if pltpu is not None and not interpret:
        try:
            params["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary"))
        except Exception:  # older API name
            pass
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[q_spec, k_spec, v_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(q, k, v)
